"""Multi-device SPMD tests (subprocess with 8 host devices).

The main pytest process keeps the default 1-device world (per project
convention: only the dry-run forces device counts), so anything needing
a mesh runs in a child interpreter with XLA_FLAGS set before jax import.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_child(code: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


@pytest.mark.slow
def test_spmd_train_step_equals_single_process():
    """The jit-level invariant: the sharded weighted train step computes
    the same loss as local single-process math on the same batch."""
    out = run_child("""
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.configs import base
        from repro.configs.base import TrainConfig, HetConfig, \\
            OptimizerConfig, ShapeConfig
        from repro.models.model import build_model
        from repro.launch import steps
        from repro import compat
        from repro.core import capacity, dummy, weighting
        from repro.data import synthetic
        import dataclasses

        cfg = dataclasses.replace(base.smoke_config("tinyllama-1.1b"),
                                  compute_dtype="float32")
        m = build_model(cfg)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        shape = ShapeConfig("t", 16, 8, "train")
        tcfg = TrainConfig(model=cfg, shape=shape,
                           het=HetConfig(accum_steps=1),
                           optimizer=OptimizerConfig(lr=0.0,
                                                     warmup_steps=1,
                                                     grad_clip=0.0))
        rec = synthetic.make_lm_records(8, 17, cfg.vocab_size, seed=3)
        plan = capacity.plan_capacities(8, [2, 1, 1, 0])
        packed = dummy.pack_global_batch(
            {"inputs": rec["inputs"][:, :16],
             "labels": rec["labels"][:, :16]}, plan)
        with compat.set_mesh(mesh):
            state = steps.init_train_state(m, tcfg, mesh,
                                           jax.random.PRNGKey(0))
            step = steps.build_train_step(m, tcfg, mesh)
            batch = {k: jnp.asarray(v) for k, v in packed.items()}
            params_before = jax.device_get(state.params)
            _, met = step(state, batch)
        spmd_loss = float(met["loss"])

        # single-process reference over the union of real rows
        ref_batch = {"inputs": jnp.asarray(rec["inputs"][:, :16]),
                     "labels": jnp.asarray(rec["labels"][:, :16]),
                     "weights": jnp.ones((8, 16))}
        o, w, _ = m.loss_fn(params_before, ref_batch)
        ref_loss = float(o / w)
        print("spmd", spmd_loss, "ref", ref_loss)
        assert abs(spmd_loss - ref_loss) < 1e-4, (spmd_loss, ref_loss)
        print("OK")
        """)
    assert "OK" in out


@pytest.mark.slow
def test_reduction_modes_agree():
    """allreduce vs hierarchical vs the bucketed engine (per-leaf and
    flat-buffer) produce identical trajectories on the exact paths;
    int8-compressed stays within quantization tolerance."""
    out = run_child("""
        import dataclasses
        import jax, jax.numpy as jnp
        from repro.configs import base
        from repro.configs.base import TrainConfig, HetConfig, \\
            OptimizerConfig, ShapeConfig
        from repro.models.model import build_model
        from repro.launch import steps
        from repro import compat
        from repro.core import capacity, dummy
        from repro.data import synthetic

        cfg = dataclasses.replace(base.smoke_config("olmo-1b"),
                                  compute_dtype="float32")
        m = build_model(cfg)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        shape = ShapeConfig("t", 16, 8, "train")
        rec = synthetic.make_lm_records(8, 17, cfg.vocab_size, seed=5)
        plan = capacity.plan_capacities(8, [1, 1, 1, 1])
        packed = dummy.pack_global_batch(
            {"inputs": rec["inputs"][:, :16],
             "labels": rec["labels"][:, :16]}, plan)

        def run(mode, compress, bucket_mb=0.0):
            tcfg = TrainConfig(model=cfg, shape=shape,
                               het=HetConfig(grad_reduction=mode,
                                             compression=compress,
                                             bucket_mb=bucket_mb),
                               optimizer=OptimizerConfig(
                                   lr=1e-3, warmup_steps=2))
            with compat.set_mesh(mesh):
                state = steps.init_train_state(m, tcfg, mesh,
                                               jax.random.PRNGKey(0))
                step = steps.build_train_step(m, tcfg, mesh)
                batch = {k: jnp.asarray(v) for k, v in packed.items()}
                losses = []
                for _ in range(4):
                    state, met = step(state, batch)
                    losses.append(float(met["loss"]))
            return losses

        base_l = run("allreduce", "none")
        hier_l = run("hierarchical", "none")
        hierb_l = run("hierarchical", "none", bucket_mb=0.05)
        comp_l = run("hierarchical", "int8")
        compb_l = run("hierarchical", "int8", bucket_mb=0.05)
        bar_l = run("bucketed_allreduce", "none", bucket_mb=0.05)
        print(base_l, hier_l, hierb_l, comp_l, compb_l, bar_l)
        for exact in (hier_l, hierb_l, bar_l):
            for a, b in zip(base_l, exact):
                assert abs(a - b) < 2e-3, (a, b)
        for comp in (comp_l, compb_l):
            for a, b in zip(base_l, comp):
                assert abs(a - b) < 3e-2, (a, b)
        assert comp_l[-1] < comp_l[0]
        assert compb_l[-1] < compb_l[0]
        print("OK")
        """)
    assert "OK" in out


@pytest.mark.slow
def test_bucketed_exchange_matches_per_leaf_psum():
    """Direct equivalence under the 8-device mesh: the bucketed
    flat-buffer exchange == per-leaf psum (exact) and stays within int8
    tolerance compressed, with error feedback capturing the residual."""
    out = run_child("""
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core import buckets as bkt
        from repro.core import hierarchical as hier

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        pods = 2
        k = jax.random.PRNGKey(0)
        tree = {"w": jax.random.normal(k, (67, 33)),
                "b": jax.random.normal(jax.random.fold_in(k, 1), (129,)),
                "s": jax.random.normal(jax.random.fold_in(k, 2),
                                       (3, 7, 5)).astype(jnp.bfloat16)}
        layout = bkt.build_layout(tree, bucket_mb=1e-3,
                                  multiple_of=pods * 256)
        stacked = jax.tree.map(
            lambda v: jnp.stack([v, (-0.5 * v.astype(jnp.float32)
                                     ).astype(v.dtype)]), tree)
        ref = jax.tree.map(
            lambda v: np.asarray(v, np.float32) * 0.5, tree)

        def bucketed(compress):
            def f(gl):
                g = jax.tree.map(lambda a: a[0], gl)
                flat = bkt.pack_buckets(g, layout)
                red, _ = bkt.exchange_buckets(
                    flat, None, axis="pod", axis_size=pods,
                    compress=compress)
                return bkt.unpack_buckets(red, layout)
            return jax.jit(compat.shard_map(
                f, mesh=mesh, in_specs=P("pod"), out_specs=P(),
                axis_names={"pod"}, check_vma=False))

        def per_leaf_psum(gl):
            g = jax.tree.map(lambda a: a[0].astype(jnp.float32), gl)
            return jax.tree.map(lambda a: jax.lax.psum(a, "pod"), g)

        exact = bucketed(False)(stacked)
        plain = jax.jit(compat.shard_map(
            per_leaf_psum, mesh=mesh, in_specs=P("pod"), out_specs=P(),
            axis_names={"pod"}, check_vma=False))(stacked)
        for a, b, c in zip(jax.tree.leaves(exact), jax.tree.leaves(ref),
                           jax.tree.leaves(plain)):
            np.testing.assert_allclose(np.asarray(a, np.float32), b,
                                       atol=2e-2)   # bf16 leaf storage
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(c, np.float32),
                atol=2e-2)
        # f32 leaves must be exact vs the per-leaf psum
        np.testing.assert_allclose(np.asarray(exact["w"]),
                                   np.asarray(plain["w"]), atol=1e-5)

        comp = bucketed(True)(stacked)
        for a, b in zip(jax.tree.leaves(comp), jax.tree.leaves(ref)):
            scale = max(1e-3, float(np.abs(b).max()))
            assert float(np.abs(np.asarray(a, np.float32) - b).max()) \\
                < 0.05 * scale + 0.02

        # 3-level bucketed hierarchical (manual over pod AND data)
        layout3 = bkt.build_layout(tree, bucket_mb=1e-3,
                                   multiple_of=2 * pods * 256)
        def f3(gl):
            g = jax.tree.map(lambda a: a[0], gl)
            out, _ = hier.hierarchical_reduce_bucketed(
                g, None, layout3, data_size=2, pod_size=pods)
            return out
        stacked4 = jax.tree.map(
            lambda v: jnp.stack([v.astype(jnp.float32)] * 4), tree)
        out3 = jax.jit(compat.shard_map(
            f3, mesh=mesh, in_specs=P(("pod", "data")), out_specs=P(),
            axis_names={"pod", "data"}, check_vma=False))(stacked4)
        for a, b in zip(jax.tree.leaves(out3), jax.tree.leaves(tree)):
            np.testing.assert_allclose(
                np.asarray(a, np.float32),
                4 * np.asarray(b, np.float32), rtol=2e-2, atol=5e-2)
        print("OK")
        """)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_cell_compiles_multi_pod():
    """One real dry-run cell on the production 512-chip mesh inside the
    child (the full grid is exercised by launch/dryrun.py)."""
    out = run_child("""
        from repro.launch import dryrun
        lowered, meta = dryrun.lower_cell("xlstm-125m", "train_4k", True)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        assert ma.temp_size_in_bytes > 0
        print("chips", meta["chips"])
        assert meta["chips"] == 512
        print("OK")
        """, devices=512)
    assert "OK" in out


@pytest.mark.slow
def test_elastic_restart_resumes_identically():
    """Checkpoint on a 2-pod mesh, restart on a 1-pod mesh (re-mesh):
    the next-step loss matches continuing on the original mesh."""
    out = run_child("""
        import jax, jax.numpy as jnp
        import numpy as np, tempfile, dataclasses
        from repro.configs import base
        from repro.configs.base import TrainConfig, HetConfig, \\
            OptimizerConfig, ShapeConfig
        from repro.models.model import build_model
        from repro.launch import steps
        from repro import compat
        from repro.core import capacity, dummy
        from repro.data import synthetic
        from repro.checkpoint.checkpoint import CheckpointManager

        cfg = dataclasses.replace(base.smoke_config("tinyllama-1.1b"),
                                  compute_dtype="float32")
        m = build_model(cfg)
        shape = ShapeConfig("t", 16, 8, "train")
        ocfg = OptimizerConfig(lr=1e-3, warmup_steps=2, grad_clip=1.0)
        rec = synthetic.make_lm_records(16, 17, cfg.vocab_size, seed=9)

        def batch_for(plan, lo, hi):
            packed = dummy.pack_global_batch(
                {"inputs": rec["inputs"][lo:hi, :16],
                 "labels": rec["labels"][lo:hi, :16]}, plan)
            return {k: jnp.asarray(v) for k, v in packed.items()}

        # phase 1: 2-pod mesh, 2 steps, checkpoint
        mesh2 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        tcfg = TrainConfig(model=cfg, shape=shape, het=HetConfig(),
                           optimizer=ocfg)
        plan4 = capacity.plan_capacities(8, [1, 1, 1, 1])
        with compat.set_mesh(mesh2):
            state = steps.init_train_state(m, tcfg, mesh2,
                                           jax.random.PRNGKey(0))
            step2 = steps.build_train_step(m, tcfg, mesh2)
            state, _ = step2(state, batch_for(plan4, 0, 8))
            host = jax.device_get(state)
            state, met_next = step2(state, batch_for(plan4, 8, 16))
        loss_continue = float(met_next["loss"])

        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d)
            mgr.save(1, host, meta={"seed": 0}, block=True)

            # phase 2: pod lost -> re-mesh to single pod, restore, resume
            mesh1 = jax.make_mesh((4, 2), ("data", "model"))
            with compat.set_mesh(mesh1):
                fresh = steps.init_train_state(m, tcfg, mesh1,
                                               jax.random.PRNGKey(0))
                restored_host, meta = mgr.restore(jax.device_get(fresh))
                specs = steps.state_specs(m, tcfg, mesh1)
                from repro.launch.sharding import named
                restored = jax.device_put(
                    type(fresh)(*restored_host), named(mesh1, specs))
                step1 = steps.build_train_step(m, tcfg, mesh1)
                # same global batch, same plan rows (4 DP ranks)
                _, met_re = step1(restored, batch_for(plan4, 8, 16))
        loss_resumed = float(met_re["loss"])
        print("continue", loss_continue, "resumed", loss_resumed)
        assert abs(loss_continue - loss_resumed) < 1e-4
        print("OK")
        """)
    assert "OK" in out
