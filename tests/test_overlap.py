"""Overlapped per-bucket pipeline + flat-view optimizer: exactness.

Single-device tests cover the packed-layout views (decay mask, segment
ids) and the flat AdamW/LAMB math against the pytree optimizers; the
pipeline itself (and the fused train step, both reduction modes,
including error-feedback state) is exercised under the 8-device mesh in
a subprocess, per the project convention that only children force
device counts.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import OptimizerConfig
from repro.core import buckets as bkt
from repro.optim import adam, lamb

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_child(code: str, devices: int = 8, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 4)
    return {
        "w": jax.random.normal(ks[0], (37, 8), jnp.float32),
        "b": jax.random.normal(ks[1], (13,), jnp.float32),
        "deep": {"m": jax.random.normal(ks[2], (5, 3, 2), jnp.float32),
                 "s": jax.random.normal(ks[3], (101,), jnp.float32)},
    }


def test_decay_mask_and_segment_ids_follow_leaf_structure():
    tree = _tree()
    layout = bkt.build_layout(tree, bucket_mb=1e-4, multiple_of=8)
    dm = np.asarray(bkt.decay_mask(layout)).reshape(-1)
    sid = np.asarray(bkt.segment_ids(layout)).reshape(-1)
    n_leaves = len(layout.sizes)
    for i, (off, n, shape) in enumerate(zip(layout.offsets, layout.sizes,
                                            layout.shapes)):
        assert (dm[off:off + n] == (1.0 if len(shape) >= 2 else 0.0)).all()
        assert (sid[off:off + n] == i).all()
    # padding: decays nothing, lands in the drop segment
    assert (dm[layout.total:] == 0.0).all()
    assert (sid[layout.total:] == n_leaves).all()


def test_apply_update_flat_bitwise_matches_tree_adam():
    """No clipping: the packed elementwise math IS apply_update."""
    params = _tree(0)
    grads = jax.tree.map(lambda p: 0.1 * p + 0.01, _tree(1))
    cfg = OptimizerConfig(grad_clip=0.0, weight_decay=0.01)
    state = adam.init_state(params, cfg)
    state = state._replace(step=jnp.asarray(3, jnp.int32))
    lr = jnp.float32(1e-3)
    new_p, new_s, _ = adam.apply_update(params, grads, state, cfg, lr)

    layout = bkt.build_layout(params, bucket_mb=1e-4, multiple_of=8)
    pb = bkt.pack_buckets(params, layout)
    gb = bkt.pack_buckets(grads, layout)
    fp, fm, fv = adam.apply_update_flat(
        pb, gb, bkt.pack_buckets(state.m, layout),
        bkt.pack_buckets(state.v, layout), state.step + 1, cfg, lr,
        decay_mask=bkt.decay_mask(layout))
    flat_tree = bkt.unpack_buckets(fp, layout)
    for a, b in zip(jax.tree.leaves(new_p), jax.tree.leaves(flat_tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(fm),
                                  np.asarray(bkt.pack_buckets(new_s.m,
                                                              layout)))
    np.testing.assert_array_equal(np.asarray(fv),
                                  np.asarray(bkt.pack_buckets(new_s.v,
                                                              layout)))


def test_apply_update_flat_clip_scale_matches_tree_clip():
    params = _tree(0)
    grads = jax.tree.map(lambda p: 2.5 * p + 0.3, _tree(1))
    cfg = OptimizerConfig(grad_clip=0.5, weight_decay=0.01)
    state = adam.init_state(params, cfg)
    lr = jnp.float32(1e-3)
    new_p, _, met = adam.apply_update(params, grads, state, cfg, lr)

    layout = bkt.build_layout(params, bucket_mb=1e-4, multiple_of=8)
    gb = bkt.pack_buckets(grads, layout)
    gnorm = jnp.sqrt(jnp.sum(gb * gb))
    # flat and per-leaf norms group the same summands differently —
    # equal to fp tolerance, not bitwise
    np.testing.assert_allclose(float(gnorm), float(met["grad_norm"]),
                               rtol=1e-6)
    cs = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    fp, _, _ = adam.apply_update_flat(
        bkt.pack_buckets(params, layout), gb,
        bkt.pack_buckets(state.m, layout),
        bkt.pack_buckets(state.v, layout), state.step + 1, cfg, lr,
        decay_mask=bkt.decay_mask(layout), clip_scale=cs)
    for a, b in zip(jax.tree.leaves(new_p),
                    jax.tree.leaves(bkt.unpack_buckets(fp, layout))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)


def test_lamb_flat_trust_ratios_match_tree_lamb():
    params = _tree(0)
    grads = jax.tree.map(lambda p: 0.2 * p + 0.05, _tree(1))
    cfg = OptimizerConfig(name="lamb", grad_clip=0.0, weight_decay=0.01)
    state = adam.init_state(params, cfg)
    lr = jnp.float32(1e-2)
    new_p, _, met = lamb.apply_update(params, grads, state, cfg, lr)

    layout = bkt.build_layout(params, bucket_mb=1e-4, multiple_of=8)
    fp, _, _, trust = lamb.apply_update_flat(
        bkt.pack_buckets(params, layout),
        bkt.pack_buckets(grads, layout),
        bkt.pack_buckets(state.m, layout),
        bkt.pack_buckets(state.v, layout), state.step + 1, cfg, lr,
        decay_mask=bkt.decay_mask(layout),
        seg_ids=bkt.segment_ids(layout), num_leaves=len(layout.sizes))
    for a, b in zip(jax.tree.leaves(new_p),
                    jax.tree.leaves(bkt.unpack_buckets(fp, layout))):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)
    np.testing.assert_allclose(float(trust), float(met["trust_ratio"]),
                               rtol=1e-5)


def test_lamb_streamed_form_bitwise_matches_barrier_form():
    """The backward-overlap flush pipeline streams LAMB per bucket
    (flat_adamw_terms + bucket_norm_terms hooks, one trailing
    apply_trust). That streamed form must be BITWISE identical to the
    whole-stack barrier ``apply_update_flat`` given the same reduced
    stack — the contract is that both compute per-leaf norms through
    the same per-bucket calls combined in the same bucket-index order
    (lamb.combine_norm_terms)."""
    params = _tree(0)
    grads = jax.tree.map(lambda p: 0.2 * p + 0.05, _tree(1))
    cfg = OptimizerConfig(name="lamb", grad_clip=0.0, weight_decay=0.01)
    state = adam.init_state(params, cfg)
    lr = jnp.float32(1e-2)
    layout = bkt.build_layout(params, bucket_mb=1e-4, multiple_of=8)
    pb = bkt.pack_buckets(params, layout)
    gb = bkt.pack_buckets(grads, layout)
    mb = bkt.pack_buckets(state.m, layout)
    vb = bkt.pack_buckets(state.v, layout)
    dmask = bkt.decay_mask(layout)
    segs = bkt.segment_ids(layout)
    n_leaves = len(layout.sizes)
    step = state.step + 1
    assert pb.ndim == 2 and pb.shape[0] > 1   # multi-bucket or vacuous

    # barrier form: one call over the whole stack
    bp, bm, bv, _ = lamb.apply_update_flat(
        pb, gb, mb, vb, step, cfg, lr, decay_mask=dmask,
        seg_ids=segs, num_leaves=n_leaves)

    # streamed form: per-bucket hooks in flush order (scrambled to
    # prove order-independence of the trailing pass), partials
    # combined in canonical bucket-index order
    rows = [None] * pb.shape[0]
    flush_order = list(reversed(range(pb.shape[0])))
    for k in flush_order:
        pf, upd, mf, vf = adam.flat_adamw_terms(
            pb[k], gb[k], mb[k], vb[k], step, cfg,
            decay_mask=dmask[k])
        psq, usq = lamb.bucket_norm_terms(pf, upd, segs[k], n_leaves)
        rows[k] = (pf, upd, mf, vf, psq, usq)
    trust = lamb.trust_from_norms(
        lamb.combine_norm_terms([r[4] for r in rows]),
        lamb.combine_norm_terms([r[5] for r in rows]))
    pf = jnp.stack([r[0] for r in rows])
    upd = jnp.stack([r[1] for r in rows])
    sp = lamb.apply_trust(pf, upd, lr, segs, trust).astype(pb.dtype)
    sm = jnp.stack([r[2] for r in rows]).astype(mb.dtype)
    sv = jnp.stack([r[3] for r in rows]).astype(vb.dtype)

    np.testing.assert_array_equal(np.asarray(bp), np.asarray(sp))
    np.testing.assert_array_equal(np.asarray(bm), np.asarray(sm))
    np.testing.assert_array_equal(np.asarray(bv), np.asarray(sv))


def test_overlap_config_validation():
    """overlap='buckets'/'backward' must refuse configs they cannot
    pipeline — one clear ValueError at build time, not a failure deep
    in the pipeline."""
    import dataclasses
    from repro.configs import base as cfgs
    from repro.configs.base import HetConfig, TrainConfig
    from repro.launch.steps import _overlap_enabled

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    model = cfgs.smoke_config("olmo-1b")
    for het, err in ((HetConfig(overlap="buckets"), "explicit"),
                     (HetConfig(overlap="backward"), "explicit"),
                     (HetConfig(overlap="buckets",
                                grad_reduction="bucketed_allreduce"),
                      "bucket_mb"),
                     (HetConfig(overlap="banana"), "not one of")):
        tcfg = TrainConfig(model=model, het=het)
        with pytest.raises(ValueError, match=err):
            _overlap_enabled(tcfg, mesh)
    ok = TrainConfig(model=model, het=HetConfig(
        overlap="buckets", grad_reduction="bucketed_allreduce",
        bucket_mb=0.05))
    assert _overlap_enabled(ok, mesh)
    none = dataclasses.replace(ok, het=HetConfig())
    assert not _overlap_enabled(none, mesh)


def test_backward_overlap_build_validation():
    """overlap='backward' model/mesh rules: scanned stacks and
    non-uniform plans are refused with actionable messages."""
    import dataclasses
    from repro.configs import base as cfgs
    from repro.configs.base import HetConfig, TrainConfig
    from repro.launch.steps import validate_train_config
    from repro.models.model import build_model

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    het = HetConfig(overlap="backward",
                    grad_reduction="bucketed_allreduce", bucket_mb=0.05)

    scanned = build_model(cfgs.smoke_config("olmo-1b"))
    with pytest.raises(ValueError, match="scan_layers"):
        validate_train_config(
            scanned, TrainConfig(model=scanned.cfg, het=het), mesh)

    xl_cfg = dataclasses.replace(cfgs.smoke_config("xlstm-125m"),
                                 scan_layers=False)
    xl = build_model(xl_cfg)
    with pytest.raises(ValueError, match="uniform"):
        validate_train_config(xl, TrainConfig(model=xl_cfg, het=het),
                              mesh)

    un_cfg = dataclasses.replace(cfgs.smoke_config("olmo-1b"),
                                 scan_layers=False)
    un = build_model(un_cfg)
    validate_train_config(un, TrainConfig(model=un_cfg, het=het), mesh)


def test_bucket_readiness_maps_layer_partition_to_buckets():
    """The readiness schedule: a bucket is flushable at the LATEST
    backward stage of any element it contains; padding never delays."""
    tree = {"emb": jnp.zeros((40,)), "layers": jnp.zeros((4, 30)),
            "z_head": jnp.zeros((25,))}
    layout = bkt.build_layout(tree, bucket_mb=40 * 4 / (1 << 20),
                              multiple_of=5)
    # flatten order: emb(40), layers(120), z_head(25); stream total 185
    L = 4
    pieces = [
        [(0, 40, L + 1)],                               # emb: last
        [(l * 30, 30, L - l) for l in range(L)],        # back-to-front
        [(0, 25, 0)],                                   # head: first
    ]
    ready = bkt.bucket_readiness(layout, pieces)
    assert len(ready) == layout.num_buckets
    be = layout.bucket_elems
    for k, r in enumerate(ready):
        stages = set()
        for (off, size), leaf_pieces in zip(
                zip(layout.offsets, layout.sizes), pieces):
            for p_off, n, stage in leaf_pieces:
                lo, hi = off + p_off, off + p_off + n
                if lo < (k + 1) * be and hi > k * be:
                    stages.add(stage)
        assert r == max(stages), (k, r, stages)
    # the bucket holding the embedding always waits for the last stage
    assert ready[0] == L + 1
    # mismatched pieces fail loudly
    with pytest.raises(ValueError, match="tile"):
        bkt.bucket_readiness(layout, [[(1, 39, 0)], pieces[1],
                                      pieces[2]])


def test_flush_pipeline_double_buffer_and_ordering():
    """BucketFlushPipeline: prep(next) issues before exchange(current),
    results assemble in bucket-index order, finish() refuses missing
    flushes."""
    readiness = (2, 0, 1, 0)            # flush order: 1, 3, 2, 0
    log = []

    def prep(k, raw_k):
        log.append(("prep", k))
        return raw_k

    def exchange(k, prepared):
        log.append(("exchange", k))
        return prepared * 10.0, None

    pipe = bkt.BucketFlushPipeline(readiness, prep, exchange)
    raw = jnp.arange(4.0)
    for stage in range(3):
        pipe.flush_ready_buckets(stage, lambda k: raw[k])
    outs, errs, _ = pipe.finish()
    assert errs is None
    np.testing.assert_array_equal(np.asarray(jnp.stack(outs)),
                                  [0.0, 10.0, 20.0, 30.0])
    # double buffer: each bucket's prep precedes the PREVIOUS bucket's
    # exchange; exchanges run in flush (readiness) order
    assert log == [("prep", 1), ("prep", 3), ("exchange", 1),
                   ("prep", 2), ("exchange", 3), ("prep", 0),
                   ("exchange", 2), ("exchange", 0)]

    pipe2 = bkt.BucketFlushPipeline(readiness, prep, exchange)
    pipe2.flush_ready_buckets(0, lambda k: raw[k])
    with pytest.raises(ValueError, match="finish"):
        pipe2.finish()


@pytest.mark.slow
def test_overlapped_exchange_bitwise_matches_monolithic():
    """Per-bucket pipeline == monolithic exchange, bit for bit (fp32
    AND int8 with error feedback, key=None), plus the 3-level
    hierarchical pipeline."""
    out = run_child("""
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core import buckets as bkt
        from repro.core import hierarchical as hier

        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        pods = 2
        rng = np.random.default_rng(0)
        tree = {"w": jnp.asarray(rng.standard_normal((130, 17)),
                                 jnp.float32),
                "b": jnp.asarray(rng.standard_normal((251,)),
                                 jnp.float32)}
        layout = bkt.build_layout(tree, bucket_mb=1e-3,
                                  multiple_of=pods * 256)
        assert layout.num_buckets >= 2
        stacked = jax.tree.map(lambda v: jnp.stack([v, -0.5 * v]), tree)

        def run(compress, overlapped, with_err):
            def f(gl):
                g = jax.tree.map(lambda a: a[0], gl)
                flat = bkt.pack_buckets(g, layout)
                e = (jnp.zeros_like(flat) + 0.01 if with_err else None)
                if overlapped:
                    red, ne, _ = bkt.exchange_buckets_overlapped(
                        flat, e, axis="pod", axis_size=pods,
                        compress=compress)
                else:
                    red, ne = bkt.exchange_buckets(
                        flat, e, axis="pod", axis_size=pods,
                        compress=compress, total=layout.total)
                return red, (ne if ne is not None else jnp.zeros(()))
            return jax.jit(compat.shard_map(
                f, mesh=mesh, in_specs=P("pod"),
                out_specs=(P(), P("pod")) if with_err else (P(), P()),
                axis_names={"pod"}, check_vma=False))(stacked)

        for compress, with_err in ((False, False), (True, False),
                                   (True, True)):
            r_m, e_m = run(compress, False, with_err)
            r_o, e_o = run(compress, True, with_err)
            np.testing.assert_array_equal(np.asarray(r_m),
                                          np.asarray(r_o))
            if with_err:
                np.testing.assert_array_equal(np.asarray(e_m),
                                              np.asarray(e_o))
        # value sanity: sum of the contributions
        ref = bkt.pack_buckets(jax.tree.map(lambda v: 0.5 * v, tree),
                               layout)
        np.testing.assert_allclose(np.asarray(r_o)[:, :256],
                                   np.asarray(ref)[:, :256], atol=0.05)

        # layout with >= 1 ALL-padding tail block: the monolithic
        # exchange skips quantizing it (exchange_buckets total=...);
        # with the reachable (zero) error tail the pipeline must still
        # agree bitwise, and the tail error must stay pinned to zero
        tree_p = {"w": jnp.asarray(rng.standard_normal((1500,)),
                                   jnp.float32)}
        layout_p = bkt.build_layout(tree_p, bucket_mb=4096 / (1 << 20),
                                    multiple_of=pods * 256)
        pad = layout_p.padded_total - layout_p.total
        assert pad >= 256, (layout_p.padded_total, layout_p.total)
        stacked_p = jax.tree.map(lambda v: jnp.stack([v, -0.5 * v]),
                                 tree_p)

        def run_pad(overlapped):
            def f(gl):
                g = jax.tree.map(lambda a: a[0], gl)
                flat = bkt.pack_buckets(g, layout_p)
                err0 = jnp.zeros_like(flat)      # reachable state
                if overlapped:
                    red, ne, _ = bkt.exchange_buckets_overlapped(
                        flat, err0, axis="pod", axis_size=pods,
                        compress=True)
                else:
                    red, ne = bkt.exchange_buckets(
                        flat, err0, axis="pod", axis_size=pods,
                        compress=True, total=layout_p.total)
                return red, ne
            return jax.jit(compat.shard_map(
                f, mesh=mesh, in_specs=P("pod"),
                out_specs=(P(), P("pod")),
                axis_names={"pod"}, check_vma=False))(stacked_p)

        r_m, e_m = run_pad(False)
        r_o, e_o = run_pad(True)
        np.testing.assert_array_equal(np.asarray(r_m), np.asarray(r_o))
        np.testing.assert_array_equal(np.asarray(e_m), np.asarray(e_o))
        tail = np.asarray(e_m).reshape(2, -1)[:, layout_p.total:]
        assert (tail == 0.0).all()

        # 3-level hierarchical pipeline (manual over pod AND data)
        layout3 = bkt.build_layout(tree, bucket_mb=1e-3,
                                   multiple_of=2 * pods * 256)
        stacked4 = jax.tree.map(
            lambda v: jnp.stack([v.astype(jnp.float32)] * 4), tree)

        def run3(overlapped, compress, with_err):
            def f(gl):
                g = jax.tree.map(lambda a: a[0], gl)
                e = (jnp.zeros((layout3.num_buckets,
                                layout3.bucket_elems // 2),
                               jnp.float32) + 0.01 if with_err else None)
                fn = (hier.hierarchical_reduce_bucketed_overlapped
                      if overlapped else hier.hierarchical_reduce_bucketed)
                out, ne = fn(g, e, layout3, data_size=2, pod_size=pods,
                             compress=compress)
                return out, (ne if ne is not None else jnp.zeros(()))
            return jax.jit(compat.shard_map(
                f, mesh=mesh, in_specs=P(("pod", "data")),
                out_specs=(P(), P(("pod", "data"))) if with_err
                else (P(), P()),
                axis_names={"pod", "data"}, check_vma=False))(stacked4)

        for compress, with_err in ((False, False), (True, True)):
            o_m, e_m = run3(False, compress, with_err)
            o_o, e_o = run3(True, compress, with_err)
            for a, b in zip(jax.tree.leaves(o_m), jax.tree.leaves(o_o)):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b))
            if with_err:
                np.testing.assert_array_equal(np.asarray(e_m),
                                              np.asarray(e_o))
        print("OK")
        """)
    assert "OK" in out


@pytest.mark.slow
def test_fused_overlap_train_step_matches_monolithic():
    """Full train steps: overlap='buckets' vs 'none' — bit-identical
    (fp32, no clip, streamed per-bucket updates), tolerance-equal with
    clipping / int8 error feedback, for BOTH reduction modes."""
    out = run_child("""
        import dataclasses
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.configs import base
        from repro.configs.base import TrainConfig, HetConfig, \\
            OptimizerConfig, ShapeConfig
        from repro.models.model import build_model
        from repro.launch import steps
        from repro import compat
        from repro.core import capacity, dummy
        from repro.data import synthetic

        cfg = dataclasses.replace(base.smoke_config("olmo-1b"),
                                  compute_dtype="float32")
        m = build_model(cfg)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        shape = ShapeConfig("t", 16, 8, "train")
        rec = synthetic.make_lm_records(8, 17, cfg.vocab_size, seed=5)
        plan = capacity.plan_capacities(8, [1, 1, 1, 1])
        packed = dummy.pack_global_batch(
            {"inputs": rec["inputs"][:, :16],
             "labels": rec["labels"][:, :16]}, plan)

        def run(mode, compress, overlap, clip):
            tcfg = TrainConfig(model=cfg, shape=shape,
                               het=HetConfig(grad_reduction=mode,
                                             compression=compress,
                                             bucket_mb=0.05,
                                             overlap=overlap),
                               optimizer=OptimizerConfig(
                                   lr=1e-3, warmup_steps=2,
                                   grad_clip=clip))
            with compat.set_mesh(mesh):
                state = steps.init_train_state(m, tcfg, mesh,
                                               jax.random.PRNGKey(0))
                step = steps.build_train_step(m, tcfg, mesh)
                batch = {k: jnp.asarray(v) for k, v in packed.items()}
                losses = []
                for _ in range(3):
                    state, met = step(state, batch)
                    losses.append(float(met["loss"]))
            return losses, jax.device_get(state)

        # streamed fused path (clip=0): bit-identical params + losses
        l0, s0 = run("bucketed_allreduce", "none", "none", 0.0)
        l1, s1 = run("bucketed_allreduce", "none", "buckets", 0.0)
        assert l0 == l1, (l0, l1)
        for a, b in zip(jax.tree.leaves(s0.params),
                        jax.tree.leaves(s1.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        # clip-barrier path: tolerance (norm-grouping differs)
        l0, s0 = run("bucketed_allreduce", "none", "none", 1.0)
        l1, s1 = run("bucketed_allreduce", "none", "buckets", 1.0)
        for a, b in zip(jax.tree.leaves(s0.params),
                        jax.tree.leaves(s1.params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=1e-5)

        # hierarchical + int8 + error feedback: err state must track
        l0, s0 = run("hierarchical", "int8", "none", 1.0)
        l1, s1 = run("hierarchical", "int8", "buckets", 1.0)
        for a, b in zip(l0, l1):
            assert abs(a - b) < 1e-4, (l0, l1)
        for a, b in zip(jax.tree.leaves(s0.params),
                        jax.tree.leaves(s1.params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=1e-5)
        np.testing.assert_allclose(np.asarray(s0.err),
                                   np.asarray(s1.err), atol=1e-6)
        assert np.any(np.asarray(s1.err) != 0.0)   # feedback is live
        print("OK")
        """, timeout=900)
    assert "OK" in out


@pytest.mark.slow
def test_backward_overlap_train_step_matches_monolithic():
    """overlap='backward' (buckets flushed DURING backprop): full train
    steps vs the monolithic path and the after-backward pipeline, with
    scan_layers=False (the unrolled program class the staged backward
    requires). fp32 grad_clip=0 is bit-identical — losses AND params —
    for both reduction modes; the clip barrier and LAMB paths are
    bit-identical to overlap='buckets' (same barrier update over the
    same reduced stack); int8 + error feedback tracks bitwise
    (per-bucket exchanges are order-independent)."""
    out = run_child("""
        import dataclasses
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.configs import base
        from repro.configs.base import TrainConfig, HetConfig, \\
            OptimizerConfig, ShapeConfig
        from repro.models.model import build_model
        from repro.launch import steps
        from repro import compat
        from repro.core import capacity, dummy
        from repro.data import synthetic

        cfg = dataclasses.replace(base.smoke_config("olmo-1b"),
                                  compute_dtype="float32",
                                  scan_layers=False)
        m = build_model(cfg)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        shape = ShapeConfig("t", 16, 8, "train")
        rec = synthetic.make_lm_records(8, 17, cfg.vocab_size, seed=5)
        plan = capacity.plan_capacities(8, [1, 1, 1, 1])
        packed = dummy.pack_global_batch(
            {"inputs": rec["inputs"][:, :16],
             "labels": rec["labels"][:, :16]}, plan)

        def run(mode, compress, overlap, clip, opt="adamw", accum=1):
            tcfg = TrainConfig(model=cfg, shape=shape,
                               het=HetConfig(grad_reduction=mode,
                                             compression=compress,
                                             bucket_mb=0.05,
                                             overlap=overlap,
                                             accum_steps=accum),
                               optimizer=OptimizerConfig(
                                   name=opt, lr=1e-3, warmup_steps=2,
                                   grad_clip=clip))
            with compat.set_mesh(mesh):
                state = steps.init_train_state(m, tcfg, mesh,
                                               jax.random.PRNGKey(0))
                step = steps.build_train_step(m, tcfg, mesh)
                batch = {k: jnp.asarray(v) for k, v in packed.items()}
                losses = []
                for _ in range(3):
                    state, met = step(state, batch)
                    losses.append(float(met["loss"]))
            return losses, jax.device_get(state)

        def assert_bitwise(s0, s1):
            for a, b in zip(jax.tree.leaves(s0.params),
                            jax.tree.leaves(s1.params)):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b))

        # fp32, clip=0, fused stream: bit-identical to the monolithic
        # path AND the after-backward pipeline (ACCEPTANCE criterion)
        l0, s0 = run("bucketed_allreduce", "none", "none", 0.0)
        l1, s1 = run("bucketed_allreduce", "none", "backward", 0.0)
        l2, s2 = run("bucketed_allreduce", "none", "buckets", 0.0)
        assert l0 == l1 == l2, (l0, l1, l2)
        assert_bitwise(s0, s1)
        assert_bitwise(s1, s2)

        # clip barrier: exchanges still flush during backprop, update
        # behind the barrier — bit-identical to the 'buckets' barrier
        l1, s1 = run("bucketed_allreduce", "none", "backward", 1.0)
        l2, s2 = run("bucketed_allreduce", "none", "buckets", 1.0)
        assert l1 == l2, (l1, l2)
        assert_bitwise(s1, s2)

        # LAMB: backward STREAMS it (per-bucket moments + norm
        # partials mid-flush, one trailing trust pass); buckets keeps
        # the whole-stack barrier — both must stay bitwise-equal
        l1, s1 = run("bucketed_allreduce", "none", "backward", 0.0,
                     opt="lamb")
        l2, s2 = run("bucketed_allreduce", "none", "buckets", 0.0,
                     opt="lamb")
        assert l1 == l2, (l1, l2)
        assert_bitwise(s1, s2)

        # hierarchical + int8 + error feedback, fused stream: the
        # per-bucket exchange is order-independent, so the flush
        # schedule must track the after-backward pipeline bitwise —
        # err state included
        l1, s1 = run("hierarchical", "int8", "backward", 0.0)
        l2, s2 = run("hierarchical", "int8", "buckets", 0.0)
        assert l1 == l2, (l1, l2)
        assert_bitwise(s1, s2)
        np.testing.assert_array_equal(np.asarray(s1.err),
                                      np.asarray(s2.err))
        assert np.any(np.asarray(s1.err) != 0.0)

        # gradient accumulation: every microbatch's backward is staged,
        # flushes fire only during the last one. Losses stay bitwise;
        # params are tolerance-equal (the monolithic whole-grad and the
        # staged per-layer VJPs compile into different fp contexts at
        # accum > 1)
        l0, s0 = run("bucketed_allreduce", "none", "none", 0.0,
                     accum=2)
        l1, s1 = run("bucketed_allreduce", "none", "backward", 0.0,
                     accum=2)
        assert l0 == l1, (l0, l1)
        for a, b in zip(jax.tree.leaves(s0.params),
                        jax.tree.leaves(s1.params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=1e-5)

        # embedding_stub frontend (no token table; inputs are (B,S,d)
        # embeddings — regression: positions must come from the
        # POST-embed activation, not inputs.shape[-1]): losses bitwise,
        # params to fp-rounding tolerance (this arch's program class
        # drifts ~1e-7 between whole-grad and staged compilation)
        scfg = dataclasses.replace(base.smoke_config("musicgen-large"),
                                   compute_dtype="float32",
                                   scan_layers=False)
        assert scfg.frontend == "embedding_stub"
        sm = build_model(scfg)
        sbatch = {
            "inputs": jnp.asarray(np.random.default_rng(1)
                                  .standard_normal((8, 16, scfg.d_model)),
                                  jnp.bfloat16),
            "labels": jnp.asarray(np.random.default_rng(2)
                                  .integers(0, scfg.vocab_size, (8, 16)),
                                  jnp.int32),
            "weights": jnp.ones((8, 16), jnp.float32),
        }

        def run_stub(overlap):
            tcfg = TrainConfig(model=scfg, shape=shape,
                               het=HetConfig(
                                   grad_reduction="bucketed_allreduce",
                                   bucket_mb=0.05, overlap=overlap),
                               optimizer=OptimizerConfig(
                                   lr=1e-3, warmup_steps=2,
                                   grad_clip=0.0))
            with compat.set_mesh(mesh):
                state = steps.init_train_state(sm, tcfg, mesh,
                                               jax.random.PRNGKey(0))
                step = steps.build_train_step(sm, tcfg, mesh)
                losses = []
                for _ in range(2):
                    state, met = step(state, sbatch)
                    losses.append(float(met["loss"]))
            return losses, jax.device_get(state)

        l0, s0 = run_stub("none")
        l1, s1 = run_stub("backward")
        assert l0 == l1, (l0, l1)
        for a, b in zip(jax.tree.leaves(s0.params),
                        jax.tree.leaves(s1.params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=1e-5)
        print("OK")
        """, timeout=1800)
    assert "OK" in out
