import functools

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: subprocess / multi-device integration tests")
    config.addinivalue_line(
        "markers",
        "pallas_interpret: Pallas kernel parity tests that run in "
        "interpret mode (skipped with a reason where even interpreted "
        "pallas_call cannot execute on this jaxlib)")


@functools.lru_cache(maxsize=1)
def _pallas_interpret_unavailable():
    """Why interpret-mode Pallas can't run here, or None if it can.

    Probed once per session with a trivial kernel. Compiled lowering is
    NOT required (the compat CPU jaxlib can't lower Pallas at all —
    that's what interpret mode is for); only a broken/absent
    jax.experimental.pallas makes the parity suites meaningless.
    """
    try:
        import jax
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def k(x_ref, o_ref):
            o_ref[...] = x_ref[...] + 1.0

        out = pl.pallas_call(
            k, out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
            interpret=True)(jnp.zeros((8,), jnp.float32))
        out.block_until_ready()
    except Exception as e:                      # pragma: no cover
        return f"{type(e).__name__}: {e}"
    return None


def pytest_collection_modifyitems(config, items):
    reason = _pallas_interpret_unavailable()
    if reason is None:
        return
    skip = pytest.mark.skip(
        reason="interpret-mode pallas_call unavailable on this jaxlib: "
               + reason)
    for item in items:                          # pragma: no cover
        if "pallas_interpret" in item.keywords:
            item.add_marker(skip)


@pytest.fixture
def pallas_interpret():
    """Force interpret mode for Pallas kernels under test.

    Returns True (the value to pass as ``interpret=``). Tests marked
    ``pallas_interpret`` are skipped wholesale — with the probe's error
    as the reason — on jaxlibs where even interpreted pallas_call
    cannot execute, so tier-1 stays green on the compat stack.
    """
    return True
