import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: subprocess / multi-device integration tests")
