"""Heterogeneous pipeline parallelism: stage planning + 1F1B schedule.

Fast tests cover the capacity-sized stage partition (core/pipeline.py:
the DP planner's largest-remainder math reused with rows=layers),
checkpoint record round-trips, the 1F1B / GPipe schedules and their
deterministic global program order, the modeled-timeline invariants,
and config validation. The end-to-end bar — the stages=2 pipelined
train step bit-identical to pure DP — runs under the 8-device mesh in
a subprocess, per the project convention that only children force
device counts.
"""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.configs import base as cfgs
from repro.configs.base import HetConfig, TrainConfig
from repro.core import capacity
from repro.core import pipeline as pipe

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_child(code: str, devices: int = 8, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-4000:]
    return proc.stdout


# --------------------------------------------------------------------------
# stage planning


def test_plan_stages_capacity_sized_contiguous():
    splan = pipe.plan_stages(12, (2.0, 1.0))
    assert splan.layers_per_stage.tolist() == [8, 4]
    assert splan.num_stages == 2
    assert splan.boundaries.tolist() == [0, 8, 12]
    assert splan.stage_ranges() == [(0, 8), (8, 12)]
    for layer in range(12):
        assert splan.stage_of_layer(layer) == (0 if layer < 8 else 1)
    with pytest.raises(ValueError, match="outside"):
        splan.stage_of_layer(12)


def test_plan_stages_every_stage_gets_a_layer():
    """Extreme skew cannot starve a stage below 1 layer (min_rows=1 —
    a stage cannot run all-dummy, the forward passes through it)."""
    splan = pipe.plan_stages(4, (1000.0, 1.0, 1.0))
    assert splan.layers_per_stage.min() >= 1
    assert int(splan.layers_per_stage.sum()) == 4


def test_plan_stages_rejects_dead_and_overcut():
    with pytest.raises(ValueError, match="must be > 0"):
        pipe.plan_stages(8, (2.0, 0.0))
    with pytest.raises(ValueError, match="must be > 0"):
        pipe.plan_stages(8, (1.0, -1.0))
    with pytest.raises(ValueError, match="non-empty"):
        pipe.plan_stages(8, ())
    with pytest.raises(ValueError, match="cannot cut"):
        pipe.plan_stages(2, (1.0, 1.0, 1.0))


def test_stage_record_roundtrip_and_malformed_rejected():
    splan = pipe.plan_stages(12, (3.0, 1.0))
    rec = pipe.stage_record(splan)
    back = pipe.stage_from_record(rec)
    assert back.num_layers == splan.num_layers
    np.testing.assert_array_equal(back.layers_per_stage,
                                  splan.layers_per_stage)
    # and through JSON, the way checkpoints carry it
    import json
    back2 = pipe.stage_from_record(json.loads(json.dumps(rec)))
    np.testing.assert_array_equal(back2.layers_per_stage,
                                  splan.layers_per_stage)

    with pytest.raises(ValueError, match="malformed"):
        pipe.stage_from_record("stages=2")
    with pytest.raises(ValueError, match="malformed"):
        pipe.stage_from_record({"num_layers": 12})   # no plan
    bad = dict(rec, num_layers=13)                   # sum mismatch
    with pytest.raises(ValueError, match="sums to"):
        pipe.stage_from_record(bad)


def test_stage_plan_for_uses_capacities_only_when_stage_shaped():
    from repro.launch.steps import stage_plan_for
    from repro.models.model import build_model

    cfg = cfgs.smoke_config("olmo-1b")
    cfg = cfg.__class__(**{**cfg.__dict__, "num_layers": 4})
    model = build_model(cfg)

    def het(stages, caps):
        return TrainConfig(model=cfg, het=HetConfig(
            pipeline_stages=stages, accum_steps=max(stages, 1),
            capacities=caps))

    assert stage_plan_for(model, het(1, ())) is None
    # stage-shaped capacities size the cut
    assert stage_plan_for(model, het(2, (3.0, 1.0))) \
        .layers_per_stage.tolist() == [3, 1]
    # DP-rank-shaped (wrong length) or zero-containing -> uniform cut
    assert stage_plan_for(model, het(2, (2.0, 1.0, 1.0, 0.0))) \
        .layers_per_stage.tolist() == [2, 2]
    assert stage_plan_for(model, het(2, ())) \
        .layers_per_stage.tolist() == [2, 2]


# --------------------------------------------------------------------------
# schedules


@pytest.mark.parametrize("schedule", pipe.SCHEDULES)
@pytest.mark.parametrize("S,M", [(1, 1), (2, 4), (3, 5), (4, 4)])
def test_stage_schedule_is_complete_and_ordered(schedule, S, M):
    sched = pipe.stage_schedule(S, M, schedule)
    assert len(sched) == S
    for s, ops in enumerate(sched):
        fwd = [m for kind, m in ops if kind == pipe.FWD]
        bwd = [m for kind, m in ops if kind == pipe.BWD]
        # every microbatch forwarded and backwarded exactly once, in
        # microbatch order (the gradient-accumulation add order)
        assert fwd == list(range(M))
        assert bwd == list(range(M))


def test_1f1b_warmup_bounds_live_microbatches():
    """Stage s holds at most S - s live forwards before its first
    backward — the memory bound that distinguishes 1F1B from GPipe."""
    S, M = 4, 8
    sched = pipe.stage_schedule(S, M, "1f1b")
    for s, ops in enumerate(sched):
        live, peak = 0, 0
        for kind, _ in ops:
            live += 1 if kind == pipe.FWD else -1
            peak = max(peak, live)
        assert peak <= S - s, (s, peak)
    # GPipe by contrast peaks at M on every stage
    gp = pipe.stage_schedule(S, M, "gpipe")
    assert all(sum(1 for k, _ in ops if k == pipe.FWD) == M
               for ops in gp)


def test_stage_schedule_rejects_bad_inputs():
    with pytest.raises(ValueError, match="schedule"):
        pipe.stage_schedule(2, 4, "interleaved")
    with pytest.raises(ValueError, match=">= 1"):
        pipe.stage_schedule(0, 4)
    with pytest.raises(ValueError, match=">= 1"):
        pipe.stage_schedule(2, 0)


@pytest.mark.parametrize("schedule", pipe.SCHEDULES)
@pytest.mark.parametrize("S,M", [(2, 2), (3, 6), (4, 5)])
def test_program_order_respects_dependencies(schedule, S, M):
    order = pipe.program_order(S, M, schedule)
    assert len(order) == len(set(order)) == 2 * S * M
    pos = {op: i for i, op in enumerate(order)}
    for m in range(M):
        for s in range(S):
            if s > 0:
                assert pos[(s, pipe.FWD, m)] > pos[(s - 1, pipe.FWD, m)]
            assert pos[(s, pipe.BWD, m)] > pos[(s, pipe.FWD, m)]
            if s < S - 1:
                assert pos[(s, pipe.BWD, m)] > pos[(s + 1, pipe.BWD, m)]


def test_program_order_backwards_per_stage_in_microbatch_order():
    """B ops of a fixed stage appear in microbatch order — per-leaf
    grad accumulation at each B event reproduces unrolled_accumulate's
    add order (the bit-exactness hook for _build_pipeline_step)."""
    for schedule in pipe.SCHEDULES:
        order = pipe.program_order(3, 5, schedule)
        for s in range(3):
            bs = [m for (st, kind, m) in order
                  if st == s and kind == pipe.BWD]
            assert bs == sorted(bs)


# --------------------------------------------------------------------------
# modeled timelines


_MODEL_KW = dict(num_microbatches=8, mb_rows=4, row_layer_time=2e-3,
                 act_bytes_per_mb=5e7, dcn_bytes_per_s=12.5e9)


def test_modeled_capacity_cut_beats_uniform_and_dp_on_skew():
    speeds = (2.0, 1.0)
    t_cap = pipe.modeled_pipeline_step_time(
        pipe.plan_stages(12, speeds), speeds, **_MODEL_KW)
    t_uni = pipe.modeled_pipeline_step_time(
        pipe.uniform_stages(12, 2), speeds, **_MODEL_KW)
    t_dp = pipe.modeled_dp_step_time(
        12, speeds, global_rows=32, row_layer_time=2e-3,
        param_bytes_per_layer=0.5e9, dcn_bytes_per_s=12.5e9)
    assert t_cap < t_uni < t_dp * 1.01
    assert t_cap < t_dp


def test_modeled_1f1b_no_worse_than_gpipe():
    speeds = (2.0, 1.0)
    splan = pipe.plan_stages(12, speeds)
    t_1f1b = pipe.modeled_pipeline_step_time(splan, speeds, **_MODEL_KW)
    t_gpipe = pipe.modeled_pipeline_step_time(splan, speeds,
                                              schedule="gpipe",
                                              **_MODEL_KW)
    assert t_1f1b <= t_gpipe


def test_modeled_uniform_cut_optimal_without_skew():
    """No skew: the uniform cut is the best capacity answer, and the
    planner produces exactly it."""
    speeds = (1.0, 1.0)
    assert pipe.plan_stages(12, speeds).layers_per_stage.tolist() == [6, 6]


def test_modeled_time_rejects_speed_shape_mismatch():
    with pytest.raises(ValueError, match="speeds"):
        pipe.modeled_pipeline_step_time(pipe.uniform_stages(12, 2),
                                        (1.0, 1.0, 1.0), **_MODEL_KW)


# --------------------------------------------------------------------------
# config validation


def test_pipeline_config_validation():
    from repro.launch.steps import validate_train_config
    from repro.models.model import build_model

    cfg = cfgs.smoke_config("olmo-1b")
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    def tcfg(model_cfg, **het_kw):
        return TrainConfig(model=model_cfg, het=HetConfig(
            pipeline_stages=2, accum_steps=2, **het_kw))

    # scanned stack: the per-stage VJP segments need the unrolled form
    scanned = build_model(cfg)
    assert cfg.scan_layers
    with pytest.raises(ValueError, match="scan_layers"):
        validate_train_config(scanned, tcfg(cfg), mesh)

    import dataclasses
    flat_cfg = dataclasses.replace(cfg, scan_layers=False)
    flat = build_model(flat_cfg)
    validate_train_config(flat, tcfg(flat_cfg), mesh)   # supported

    # more stages than layers
    thin_cfg = dataclasses.replace(cfg, scan_layers=False, num_layers=1)
    thin = build_model(thin_cfg)
    with pytest.raises(ValueError, match="pipeline_stages"):
        validate_train_config(thin, tcfg(thin_cfg), mesh)

    # a pipe mesh axis must be sized to pipeline_stages
    pipe_mesh = jax.make_mesh((1, 1, 1), ("pipe", "data", "model"))
    with pytest.raises(ValueError, match="pipe"):
        validate_train_config(flat, tcfg(flat_cfg), pipe_mesh)

    # HetConfig.validate owns the mesh-independent combos
    with pytest.raises(ValueError, match="accum_steps"):
        HetConfig(pipeline_stages=2, accum_steps=1).validate()
    with pytest.raises(ValueError, match="overlap"):
        HetConfig(pipeline_stages=2, accum_steps=2,
                  overlap="buckets", bucket_mb=1.0,
                  grad_reduction="bucketed_allreduce").validate()
    with pytest.raises(ValueError, match="hierarchical"):
        HetConfig(pipeline_stages=2, accum_steps=2,
                  grad_reduction="hierarchical").validate()
    with pytest.raises(ValueError, match="canonical"):
        HetConfig(pipeline_stages=2, accum_steps=2,
                  weighting="canonical").validate()


def test_checkpoint_format_records_stage_plan():
    import dataclasses
    from repro.launch import steps
    from repro.models.model import build_model

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cfg = dataclasses.replace(cfgs.smoke_config("olmo-1b"),
                              scan_layers=False, num_layers=4)
    model = build_model(cfg)
    tcfg = TrainConfig(model=cfg, het=HetConfig(
        pipeline_stages=2, accum_steps=2, capacities=(3.0, 1.0)))
    fmt = steps.checkpoint_format(model, tcfg, mesh)
    assert fmt["pipeline"]["num_layers"] == 4
    assert fmt["pipeline"]["plan"]["rows_per_rank"] == [3, 1]
    back = pipe.stage_from_record(fmt["pipeline"])
    assert back.layers_per_stage.tolist() == [3, 1]

    plain = TrainConfig(model=cfg, het=HetConfig())
    assert steps.checkpoint_format(model, plain, mesh)["pipeline"] \
        is None


# --------------------------------------------------------------------------
# the end-to-end bar: pipelined step == pure DP


@pytest.mark.slow
def test_pipeline_step_matches_pure_dp():
    """stages=2 1F1B over the (pod, data, model) mesh vs stages=1 pure
    DP on the same global batch: fp32/clip=0/allreduce is bit-identical
    (losses AND params, AdamW and LAMB, gpipe too — the schedule is
    not a numeric); the bucketed engine keeps losses bitwise with
    params at fp-rounding level (XLA fuses the attention backward
    differently at any VJP cut)."""
    out = run_child("""
        import dataclasses
        import jax, jax.numpy as jnp
        import numpy as np
        from repro.configs import base
        from repro.configs.base import TrainConfig, HetConfig, \\
            OptimizerConfig, ShapeConfig
        from repro.models.model import build_model
        from repro.launch import steps
        from repro import compat
        from repro.core import capacity, dummy
        from repro.data import synthetic

        cfg = dataclasses.replace(base.smoke_config("olmo-1b"),
                                  compute_dtype="float32",
                                  scan_layers=False)
        m = build_model(cfg)
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        shape = ShapeConfig("t", 16, 8, "train")
        rec = synthetic.make_lm_records(16, 17, cfg.vocab_size, seed=5)
        plan = capacity.plan_capacities(16, [1, 1, 1, 1])
        packed = dummy.pack_global_batch(
            {"inputs": rec["inputs"][:, :16],
             "labels": rec["labels"][:, :16]}, plan)
        batch = {k: jnp.asarray(v) for k, v in packed.items()}

        def run(stages, mode="allreduce", opt="adamw",
                schedule="1f1b"):
            tcfg = TrainConfig(model=cfg, shape=shape,
                het=HetConfig(grad_reduction=mode,
                              bucket_mb=0.05 if mode != "allreduce"
                              else 0.0,
                              accum_steps=4, pipeline_stages=stages,
                              pipeline_schedule=schedule),
                optimizer=OptimizerConfig(name=opt, lr=1e-3,
                                          warmup_steps=2,
                                          grad_clip=0.0))
            with compat.set_mesh(mesh):
                state = steps.init_train_state(m, tcfg, mesh,
                                               jax.random.PRNGKey(0))
                step = steps.build_train_step(m, tcfg, mesh)
                losses = []
                for _ in range(2):
                    state, met = step(state, batch)
                    losses.append(float(met["loss"]))
            return losses, jax.device_get(state)

        def bitwise(s0, s1):
            for a, b in zip(jax.tree.leaves(s0.params),
                            jax.tree.leaves(s1.params)):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b))

        l0, s0 = run(1)
        l1, s1 = run(2)
        assert l0 == l1, (l0, l1)
        bitwise(s0, s1)

        lg, sg = run(2, schedule="gpipe")
        assert l0 == lg, (l0, lg)
        bitwise(s0, sg)

        l4, s4 = run(1, opt="lamb")
        l5, s5 = run(2, opt="lamb")
        assert l4 == l5, (l4, l5)
        bitwise(s4, s5)

        l2, s2 = run(1, mode="bucketed_allreduce")
        l3, s3 = run(2, mode="bucketed_allreduce")
        assert l2 == l3, (l2, l3)
        for a, b in zip(jax.tree.leaves(s2.params),
                        jax.tree.leaves(s3.params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=0, atol=1e-6)
        print("OK")
        """, timeout=1200)
    assert "OK" in out
