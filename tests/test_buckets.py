"""Bucketed flat-buffer reduction: layout, pack/unpack, byte models.

Single-device tests of core/buckets.py (the collective exchange itself
is exercised under the 8-device mesh in test_distributed.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import buckets as bkt
from repro.core import compression


def _mixed_tree():
    """Mixed dtypes, odd sizes, nested containers — the hard cases."""
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 5)
    return {
        "embed": jax.random.normal(ks[0], (37, 8), jnp.float32),
        "blocks": [
            {"w": jax.random.normal(ks[1], (13, 13)).astype(jnp.bfloat16),
             "b": jax.random.normal(ks[2], (13,), jnp.float32)},
            {"w": jax.random.normal(ks[3], (5, 3, 2)).astype(jnp.bfloat16),
             "b": jnp.float32(1.5)},                      # scalar leaf
        ],
        "head": jax.random.normal(ks[4], (101,), jnp.float32),
    }


def test_layout_covers_every_leaf_contiguously():
    tree = _mixed_tree()
    layout = bkt.build_layout(tree, bucket_mb=1e-4, multiple_of=8)
    assert layout.total == sum(
        int(np.prod(l.shape)) if l.shape else 1
        for l in jax.tree.leaves(tree))
    # offsets are a contiguous partition of [0, total)
    ends = [o + s for o, s in zip(layout.offsets, layout.sizes)]
    assert list(layout.offsets) == [0] + ends[:-1]
    assert ends[-1] == layout.total
    # fixed-size grid: padded total is a whole number of aligned buckets
    assert layout.padded_total == layout.num_buckets * layout.bucket_elems
    assert layout.bucket_elems % 8 == 0
    assert layout.padded_total >= layout.total
    assert layout.padded_total - layout.total < layout.bucket_elems


def test_pack_unpack_roundtrip_mixed_dtypes():
    tree = _mixed_tree()
    layout = bkt.build_layout(tree, bucket_mb=1e-4, multiple_of=4)
    packed = bkt.pack_buckets(tree, layout)
    assert packed.shape == (layout.num_buckets, layout.bucket_elems)
    assert packed.dtype == jnp.float32
    back = bkt.unpack_buckets(packed, layout)
    flat_in, td_in = jax.tree.flatten(tree)
    flat_out, td_out = jax.tree.flatten(back)
    assert td_in == td_out
    for a, b in zip(flat_in, flat_out):
        assert jnp.asarray(a).dtype == jnp.asarray(b).dtype
        assert jnp.asarray(a).shape == jnp.asarray(b).shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_pack_rejects_mismatched_tree():
    tree = _mixed_tree()
    layout = bkt.build_layout(tree, bucket_mb=1e-4)
    with pytest.raises(ValueError, match="leaves"):
        bkt.pack_buckets({"only": jnp.zeros((3,))}, layout)


def test_layout_bucket_count_matches_ceil_bound():
    tree = {"w": jnp.zeros((1000,))}
    layout = bkt.build_layout(tree, bucket_mb=256 * 4 / (1 << 20),
                              multiple_of=256)          # 256-elem buckets
    assert layout.bucket_elems == 256
    assert layout.num_buckets == -(-1000 // 256)        # ceil = 4
    # a giant bucket_mb collapses to one padded bucket, never more pad
    # than one bucket
    big = bkt.build_layout(tree, bucket_mb=64.0, multiple_of=256)
    assert big.num_buckets == 1
    assert big.padded_total - big.total < big.bucket_elems + 256


def test_build_layout_works_on_shape_structs():
    shapes = {"a": jax.ShapeDtypeStruct((7, 3), jnp.bfloat16),
              "b": jax.ShapeDtypeStruct((11,), jnp.float32)}
    layout = bkt.build_layout(shapes, bucket_mb=1e-5, multiple_of=2)
    assert layout.total == 32
    err = bkt.init_error_buckets(layout)
    assert err.shape == (layout.num_buckets, layout.bucket_elems)
    assert layout.error_shape(4) == (4,) + err.shape


def test_payload_fuse_split_roundtrip():
    q = jnp.arange(-64, 64, dtype=jnp.int8).reshape(2, 64)
    s = jnp.array([0.5, -3.25e-5], jnp.float32)
    payload = compression.fuse_payload(q, s)
    q2, s2 = compression.split_payload(payload, 64)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s2))


def test_modeled_bytes_compression_and_scaling():
    tree = {"w": jnp.zeros((1 << 16,))}
    layout = bkt.build_layout(tree, bucket_mb=0.05, multiple_of=512)
    exact = bkt.modeled_link_bytes(layout, ranks=8, compress=False)
    comp = bkt.modeled_link_bytes(layout, ranks=8, compress=True)
    # int8 + fused scales ~ 3.9x fewer bytes than fp32
    assert 3.0 < exact / comp < 4.2
    # the legacy compressed per-leaf path pays O(ranks) receive bytes:
    # (p-1) full payloads vs the bucketed ~2 (p-1)/p — p/2 x more at p=8
    legacy = bkt.modeled_per_leaf_bytes(tree, ranks=8, compress=True)
    assert legacy > 3 * comp
    # uncompressed per-leaf ~ bucketed (both ~2x shard); bucketed only
    # adds padding
    legacy_exact = bkt.modeled_per_leaf_bytes(tree, ranks=8, compress=False)
    assert abs(legacy_exact - exact) / exact < 0.1


def test_exchange_rejects_misaligned_layout():
    buckets = jnp.zeros((2, 10))
    with pytest.raises(ValueError, match="not divisible"):
        bkt.exchange_buckets(buckets, None, axis="pod", axis_size=4)
    with pytest.raises(ValueError, match="block_size"):
        bkt.exchange_buckets(jnp.zeros((2, 8)), None, axis="pod",
                             axis_size=2, compress=True, block_size=256)
