"""Serving-engine tests: paged-attention parity at ragged depths (GQA
and absorbed-MLA), block allocator / capacity router / scheduler
bookkeeping, and the compile-once property of the jitted decode step."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compat
from repro.configs import base as cfgbase
from repro.launch import serve as serve_mod
from repro.launch import steps as steps_mod
from repro.models.kvcache import PagedLayout
from repro.models.model import build_model
from repro.serve import (BlockPool, CapacityRouter, Request, Scheduler,
                         pod_block_pools)
from repro.serve.engine import _trace_count
from repro.serve.scheduler import default_bucket_lens

# one GQA and one absorbed-MLA architecture exercise both paged layouts
PAGED_ARCHS = ["olmo-1b", "deepseek-v2-236b"]


def _model(arch, **over):
    cfg = dataclasses.replace(cfgbase.smoke_config(arch), **over)
    model = build_model(cfg)
    params = jax.jit(model.init_params)(jax.random.PRNGKey(0))
    return cfg, model, params


def _disjoint_tables(batch, mb):
    return jnp.asarray([[b * mb + j for j in range(mb)]
                        for b in range(batch)], jnp.int32)


def _ragged_setup(arch, **over):
    """Three sequences at depths 5/9/12 inside one 16-position layout:
    prefill 12 bucket-padded tokens, then one decode step at each
    sequence's own kv_len."""
    cfg, model, params = _model(arch, **over)
    rng = np.random.default_rng(1)
    bs, batch, s_pad = 4, 3, 12
    lens = np.array([5, 9, 12], np.int32)
    layout = PagedLayout(block_size=bs, num_blocks=batch * 4,
                         max_blocks_per_seq=4)     # 16 positions
    x = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, 16)),
                    jnp.int32)
    tables = _disjoint_tables(batch, 4)
    cache = model.init_paged_cache(layout)
    lg_pre, cache = model.prefill_paged(params, x[:, :s_pad],
                                        jnp.asarray(lens), cache, tables)
    nxt = x[np.arange(batch), lens]               # token at each depth
    lg_dec, _ = model.decode_paged(params, nxt, cache, tables,
                                   jnp.asarray(lens))
    # reference: full-context forward of the same tokens, read at each
    # sequence's own position (causal => trailing rows are inert)
    full = model.logits_fn(params, x)
    ref_pre = np.asarray(full)[np.arange(batch), lens - 1]
    ref_dec = np.asarray(full)[np.arange(batch), lens]
    aux = (model, params, x, lens, s_pad, layout,
           np.asarray(lg_pre))
    return np.asarray(lg_pre), np.asarray(lg_dec), ref_pre, ref_dec, aux


def _contiguous_refs(model, params, x, lens, s_pad, layout):
    """The pre-paging serving path at the same tensor shapes as the
    paged one: contiguous cache sized to the paged gather width
    (max_blocks_per_seq * block_size), full-batch prefill over the same
    bucket-padded inputs, then one scalar-position decode call per
    distinct depth (row b read at its own pos — the other rows are
    computed but discarded). Identical shapes everywhere mean identical
    fp32 reduction trees, so the comparison can demand bit-equality."""
    batch = x.shape[0]
    last_logits, cache = model.prefill(params, x[:, :s_pad],
                                       max_len=layout.max_seq_len)
    nxt = jnp.asarray(x[np.arange(batch), lens])
    rows = [np.asarray(model.decode(params, nxt, cache,
                                    jnp.int32(int(lens[b])))[0])[b]
            for b in range(batch)]
    return np.asarray(last_logits), np.stack(rows)


@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_paged_ragged_decode_bitwise_fp32(arch):
    """fp32 + dense attention: the paged path (block scatter/gather,
    bucket padding, per-sequence kv_len masks) must be bit-identical to
    the contiguous-cache path over the same tokens at the same shapes —
    any drift means block indexing or the padding masks leak into the
    math. (The full-context forward runs at a different sequence length
    => different reduction trees; it is the TOLERANCE reference below.)"""
    lg_pre, lg_dec, ref_pre, ref_dec, aux = _ragged_setup(
        arch, compute_dtype="float32", attention_impl="dense")
    model, params, x, lens, s_pad, layout, _ = aux
    cont_pre, cont_dec = _contiguous_refs(model, params, x, lens,
                                          s_pad, layout)
    # contiguous prefill only reports the final position: row 2's real
    # length equals the bucket, so its ragged read lands there
    np.testing.assert_array_equal(lg_pre[2], cont_pre[2])
    np.testing.assert_array_equal(lg_dec, cont_dec)
    # and rounding-level agreement with the full-context forward
    assert np.max(np.abs(lg_pre - ref_pre)) < 1e-4
    assert np.max(np.abs(lg_dec - ref_dec)) < 1e-4


@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_paged_ragged_decode_tolerance_compute_dtype(arch):
    """Default compute dtype (+ the arch's own attention impl): paged
    and full-context logits agree to rounding, and pick the same next
    token at every ragged depth."""
    lg_pre, lg_dec, ref_pre, ref_dec, _ = _ragged_setup(arch)
    scale = max(1.0, float(np.max(np.abs(ref_dec))))
    assert np.max(np.abs(lg_pre - ref_pre)) < 6e-2 * scale
    assert np.max(np.abs(lg_dec - ref_dec)) < 6e-2 * scale
    np.testing.assert_array_equal(np.argmax(lg_dec, -1),
                                  np.argmax(ref_dec, -1))


def test_paged_layout_validation():
    layout = PagedLayout(block_size=4, num_blocks=8, max_blocks_per_seq=2)
    assert layout.null_block == 8
    assert layout.max_seq_len == 8
    assert layout.blocks_for(1) == 1 and layout.blocks_for(5) == 2
    with pytest.raises(ValueError):
        PagedLayout(block_size=0, num_blocks=8, max_blocks_per_seq=2)
    with pytest.raises(ValueError):
        PagedLayout(block_size=4, num_blocks=0, max_blocks_per_seq=2)


def test_block_pool_alloc_free():
    layout = PagedLayout(block_size=4, num_blocks=6, max_blocks_per_seq=3)
    pool = BlockPool(layout)
    a = pool.alloc(4)
    assert len(set(a)) == 4 and pool.num_free == 2
    with pytest.raises(RuntimeError):
        pool.alloc(3)                       # only 2 left
    pool.free(a[:2])
    assert pool.num_free == 4
    with pytest.raises(RuntimeError):
        pool.free(a[:1])                    # double free
    # pod extents partition the pool disjointly
    pools = pod_block_pools(layout, 2)
    blocks = pools[0].alloc(pools[0].num_blocks) + \
        pools[1].alloc(pools[1].num_blocks)
    assert sorted(blocks) == list(range(6))


def test_capacity_router_limits_and_route():
    r = CapacityRouter(7, [1.0, 0.5, 0.25])
    assert sum(r.limits) == 7
    assert list(r.limits) == sorted(r.limits, reverse=True)
    # empty pods: fastest wins; then fills proportionally
    assert r.route([0, 0, 0]) == 0
    assert r.route([r.limits[0], 0, 0]) == 1
    assert r.route(list(r.limits)) is None  # all full
    with pytest.raises(ValueError):
        CapacityRouter(0, [1.0])
    with pytest.raises(ValueError):
        CapacityRouter(4, [0.0, 0.0])


def _sched(slots=2, num_blocks=8, mb=4, speeds=(1.0,)):
    layout = PagedLayout(block_size=4, num_blocks=num_blocks,
                         max_blocks_per_seq=mb)
    return Scheduler(layout, CapacityRouter(slots, speeds), slots), layout


def test_scheduler_submit_validation():
    sched, layout = _sched()
    with pytest.raises(ValueError):
        sched.submit(Request(0, (), 4))              # empty prompt
    with pytest.raises(ValueError):
        sched.submit(Request(0, (1,), 0))            # no token budget
    with pytest.raises(ValueError):
        sched.submit(Request(0, (1,) * 15, 4))       # > max_seq_len
    assert default_bucket_lens(layout) == (4, 8, 16)


def test_scheduler_fifo_and_slot_reuse():
    sched, _ = _sched(slots=2, num_blocks=8)
    for rid in range(3):
        sched.submit(Request(rid, (1, 2, 3), 2))
    admitted = sched.try_admit()
    assert [s.rid for s in admitted] == [0, 1]       # FIFO, slots=2
    assert sched.try_admit() == []                   # no free slot
    done = admitted[0]
    done.generated = [7, 7]
    sched.finish(done)
    nxt = sched.try_admit()
    assert [s.rid for s in nxt] == [2]
    assert nxt[0].slot == done.slot                  # slot recycled
    assert sched.allocated_blocks() == 2


def test_scheduler_preempts_newest_when_blocks_run_out():
    # 3 blocks total, 2 sequences each holding 1 and growing: when the
    # pool dries up the NEWEST admission is evicted and re-queued at
    # the queue front with its generated tokens folded into the prompt
    sched, _ = _sched(slots=2, num_blocks=3)
    sched.submit(Request(0, (1, 2, 3), 8))
    sched.submit(Request(1, (4, 5, 6), 8))
    s0, s1 = sched.try_admit()
    s0.kv_len, s1.kv_len = 4, 4                      # both need block 2
    s0.generated = [9]
    s1.generated = [8]
    assert sched.ensure_next_block(s0)               # takes the last one
    assert sched.ensure_next_block(s1) is False      # s1 preempts itself
    assert sched.preemptions == 1
    req = sched.waiting[0]
    assert req.rid == 1 and req.prompt == (4, 5, 6, 8)
    assert req.max_new_tokens == 7
    assert sched.active_per_pod == [1]


def test_decode_step_compiles_once():
    """One engine run over mixed lengths + staggered arrivals compiles
    the decode step exactly once (fixed shapes, donated cache)."""
    cfg, model, _ = _model("olmo-1b", compute_dtype="float32",
                           attention_impl="dense")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = steps_mod.init_params_sharded(model, mesh,
                                           jax.random.PRNGKey(0))
    layout = PagedLayout(block_size=4, num_blocks=12,
                         max_blocks_per_seq=4)
    reqs = [Request(0, (1, 2, 3), 4, 0.0),
            Request(1, tuple(range(1, 8)), 3, 0.5),
            Request(2, (9, 8), 5, 4.0)]
    with compat.set_mesh(mesh):
        eng = serve_mod.build_engine(model, params, mesh, layout,
                                     slots=2, prefill_batch=2,
                                     pod_speeds=[1.0])
        res = eng.run(reqs)
    assert _trace_count(eng.decode_fn) == 1
    assert {r: len(t) for r, t in res.tokens.items()} == {0: 4, 1: 3,
                                                          2: 5}
    assert res.stats["decode_steps"] > 0
    assert res.stats["block_util_peak"] <= 1.0


# --------------------------------------------------------------------------
# PR 9: pallas decode kernels on the engine hot path
# --------------------------------------------------------------------------


_PR9_REQS = [Request(0, (1, 2, 3), 4, 0.0),
             Request(1, tuple(range(1, 8)), 3, 0.5),
             Request(2, (9, 8), 5, 4.0)]


def _engine_run(impl):
    cfg, model, _ = _model("olmo-1b", compute_dtype="float32",
                           attention_impl=impl)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = steps_mod.init_params_sharded(model, mesh,
                                           jax.random.PRNGKey(0))
    layout = PagedLayout(block_size=4, num_blocks=12,
                         max_blocks_per_seq=4)
    with compat.set_mesh(mesh):
        eng = serve_mod.build_engine(model, params, mesh, layout,
                                     slots=2, prefill_batch=2,
                                     pod_speeds=[1.0])
        res = eng.run(list(_PR9_REQS))
    return eng, res


@pytest.mark.pallas_interpret
def test_engine_pallas_token_identical_to_reference():
    """A full compile-once engine run with attention_impl='pallas'
    (in-kernel block gather, interpret-mode on CPU) emits exactly the
    same tokens as the reference engine on the same trace — the fp32-
    bitwise kernel parity surviving scatter, scheduling and argmax."""
    eng_ref, res_ref = _engine_run("reference")
    eng_pal, res_pal = _engine_run("pallas")
    assert _trace_count(eng_pal.decode_fn) == 1
    assert res_ref.stats["attention_impl"] == "reference"
    assert res_pal.stats["attention_impl"] == "pallas"
    assert res_pal.tokens == res_ref.tokens
    assert res_pal.stats["decode_steps"] == res_ref.stats["decode_steps"]


@pytest.mark.pallas_interpret
def test_engine_pallas_retrace_guard_still_fires():
    """The fixed-shape fail-loud contract survives the kernel swap:
    poking the pallas decode step with a wider slot batch after a clean
    run makes _assert_no_retrace raise."""
    eng, _ = _engine_run("pallas")
    assert _trace_count(eng.decode_fn) == 1
    layout = PagedLayout(block_size=4, num_blocks=12,
                         max_blocks_per_seq=4)
    wide = 3                                  # engine compiled slots=2
    tables = jnp.full((wide, 4), layout.null_block, jnp.int32)
    tables = tables.at[:, 0].set(jnp.arange(wide))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with compat.set_mesh(mesh):
        cache = eng.init_cache_fn()
        eng.decode_fn(jnp.zeros((wide,), jnp.int32), cache, tables,
                      jnp.zeros((wide,), jnp.int32))
    with pytest.raises(RuntimeError, match="retraced"):
        eng._assert_no_retrace()


def test_serve_batch_spec_warns_once_per_build(caplog, monkeypatch):
    """Regression: the replicated-batch fallback warning fires once at
    step-BUILD time, not once per decode step — 3 decode steps after a
    non-divisible build must add no further warnings."""
    import logging

    cfg, model, _ = _model("olmo-1b", compute_dtype="float32")
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = steps_mod.init_params_sharded(model, mesh,
                                           jax.random.PRNGKey(0))
    layout = PagedLayout(block_size=4, num_blocks=12,
                         max_blocks_per_seq=4)
    # pretend the mesh has a DP extent of 2 so slots=3 is non-divisible
    monkeypatch.setattr(steps_mod, "dp_size", lambda m: 2)
    slots = 3
    with caplog.at_level(logging.WARNING, logger="repro.launch.steps"):
        with compat.set_mesh(mesh):
            decode = steps_mod.build_paged_decode_step(model, mesh,
                                                       layout, slots)
            cache = jax.jit(functools.partial(model.init_paged_cache,
                                              layout))()
            tables = jnp.full((slots, 4), layout.null_block, jnp.int32)
            tables = tables.at[:, 0].set(jnp.arange(slots))
            kv_lens = jnp.zeros((slots,), jnp.int32)
            toks = jnp.zeros((slots,), jnp.int32)
            for _ in range(3):
                _, cache = decode(params, toks, cache, tables, kv_lens)
    warns = [r for r in caplog.records
             if "FULLY-REPLICATED" in r.getMessage()]
    assert len(warns) == 1, (
        f"expected exactly one build-time fallback warning, got "
        f"{len(warns)}")
