"""Optimizer, LR schedules, checkpoint manager."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.base import OptimizerConfig
from repro.optim import adam, schedules


def test_adam_converges_quadratic():
    cfg = OptimizerConfig(lr=0.1, schedule="constant", warmup_steps=1,
                          grad_clip=0.0, weight_decay=0.0)
    params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((3,))}
    st = adam.init_state(params, cfg)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum((p["b"] - 1.0) ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        lr = schedules.learning_rate(cfg, st.step + 1)
        params, st, _ = adam.apply_update(params, g, st, cfg, lr)
    assert float(loss(params)) < 0.05 * l0
    assert int(st.step) == 60


def test_adam_dtype_policy():
    cfg = OptimizerConfig(m_dtype="bfloat16", v_dtype="float32")
    params = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    st = adam.init_state(params, cfg)
    assert st.m["w"].dtype == jnp.bfloat16
    assert st.v["w"].dtype == jnp.float32
    g = {"w": jnp.full((8, 8), 0.1, jnp.bfloat16)}
    p2, st2, _ = adam.apply_update(params, g, st, cfg, jnp.float32(1e-2))
    assert p2["w"].dtype == jnp.bfloat16
    assert st2.m["w"].dtype == jnp.bfloat16


def test_grad_clip():
    g = {"w": jnp.full((100,), 10.0)}
    clipped, norm = adam.clip_by_global_norm(g, 1.0)
    assert abs(float(adam.global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) == pytest.approx(100.0)


@pytest.mark.parametrize("sch", ["inverse_sqrt", "linear", "cosine",
                                 "constant"])
def test_schedule_shapes(sch):
    cfg = OptimizerConfig(lr=1e-3, schedule=sch, warmup_steps=100,
                          total_steps=1000)
    lr_w = float(schedules.learning_rate(cfg, jnp.int32(50)))
    lr_peak = float(schedules.learning_rate(cfg, jnp.int32(100)))
    lr_late = float(schedules.learning_rate(cfg, jnp.int32(900)))
    assert lr_w < lr_peak == pytest.approx(1e-3)
    if sch != "constant":
        assert lr_late < lr_peak


def test_checkpoint_roundtrip_rotation_and_atomicity(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
             "step": jnp.int32(7)}
    for s in (10, 20, 30):
        mgr.save(s, state, meta={"epoch": s // 10, "seed": 42})
    mgr.wait()
    assert mgr.all_steps() == [20, 30]
    restored, meta = mgr.restore(state)
    assert meta["step"] == 30 and meta["seed"] == 42
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(state["params"]["w"]))
    # a partial (un-committed) directory is ignored
    os.makedirs(str(tmp_path / "step_0000000040"))
    assert mgr.latest_step() == 30


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=1)
    mgr.save(1, {"w": jnp.zeros((4,))}, block=True)
    with pytest.raises(ValueError, match="shape mismatch"):
        mgr.restore({"w": jnp.zeros((5,))})


def test_checkpoint_carries_hetseq_metadata(tmp_path):
    """The paper's checkpoint contract: epoch, step, optimizer state,
    seed — plus our capacity plan for exact elastic resume."""
    mgr = CheckpointManager(str(tmp_path), keep=1)
    from repro.core.capacity import plan_capacities
    plan = plan_capacities(16, [2, 1, 1])
    meta = {"epoch": 3, "seed": 123,
            "plan_rows": plan.rows_per_rank.tolist(),
            "capacities": plan.capacities.tolist()}
    mgr.save(500, {"w": jnp.ones((2,))}, meta=meta, block=True)
    _, m = mgr.restore({"w": jnp.ones((2,))})
    assert m["plan_rows"] == [8, 4, 4]
    assert m["epoch"] == 3 and m["seed"] == 123 and m["step"] == 500


def test_lamb_converges_and_reports_trust():
    """LAMB (the paper's stated future work, You et al. 2019):
    converges on a quadratic and emits per-layer trust ratios."""
    from repro.optim import lamb
    cfg = OptimizerConfig(name="lamb", lr=0.1, schedule="constant",
                          warmup_steps=1, grad_clip=0.0,
                          weight_decay=0.01)
    params = {"w": jnp.ones((8, 8)) * 2.0, "b": jnp.zeros((4,))}
    st = adam.init_state(params, cfg)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum((p["b"] - 1.0) ** 2)

    l0 = float(loss(params))
    for _ in range(80):
        g = jax.grad(loss)(params)
        lr = schedules.learning_rate(cfg, st.step + 1)
        params, st, met = lamb.apply_update(params, g, st, cfg, lr)
    assert float(loss(params)) < 0.05 * l0
    assert float(met["trust_ratio"]) > 0.0


def test_lamb_state_compatible_with_adam_checkpoints(tmp_path):
    """LAMB shares AdamState: a checkpoint written under adamw restores
    under lamb (optimizer swap on resume, heterogeneous fleets)."""
    from repro.optim import lamb
    cfg = OptimizerConfig(name="adamw")
    params = {"w": jnp.ones((4, 4))}
    st = adam.init_state(params, cfg)._replace(step=jnp.int32(5))
    mgr = CheckpointManager(str(tmp_path), keep=1)
    mgr.save(5, {"opt": st._asdict()}, block=True)
    restored, _ = mgr.restore({"opt": st._asdict()})
    st2 = adam.AdamState(**restored["opt"])
    p2, st3, _ = lamb.apply_update(
        params, {"w": jnp.full((4, 4), 0.1)}, st2,
        OptimizerConfig(name="lamb"), jnp.float32(1e-3))
    assert int(st3.step) == 6
