"""Roofline HLO analyzer: trip-count weighting, collective accounting."""
import textwrap

import pytest

from repro.roofline import hlo as H
from repro.roofline.report import RooflineRow

SYNTH = textwrap.dedent("""\
    HloModule jit_step, is_scheduled=true

    %body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %p = (s32[], f32[8,16]{1,0}) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
      %w = f32[16,16]{1,0} constant({...})
      %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16]{1,0} all-reduce(%dot.1), channel_id=1, replica_groups={{0,1,2,3}}, to_apply=%add
      %one = s32[] constant(1)
      %i2 = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%i2, %ar)
    }

    %cond (p: (s32[], f32[8,16])) -> pred[] {
      %p = (s32[], f32[8,16]{1,0}) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %n = s32[] constant(5)
      ROOT %lt = pred[] compare(%i, %n), direction=LT
    }

    %add (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (arg: f32[8,16]) -> (s32[], f32[8,16]) {
      %arg = f32[8,16]{1,0} parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[8,16]{1,0}) tuple(%zero, %arg)
      %w2 = f32[16,4]{1,0} constant({...})
      %dot.2 = f32[8,4]{1,0} dot(%arg, %w2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ag = f32[32,4]{1,0} all-gather(%dot.2), channel_id=2, replica_groups=[256,2]<=[2,256]T(1,0), dimensions={0}
      ROOT %wh = (s32[], f32[8,16]{1,0}) while(%init), condition=%cond, body=%while_body_alias
    }
    """).replace("%while_body_alias", "%body")


def test_split_computations():
    comps = H._split_computations(SYNTH)
    assert set(comps) == {"body", "cond", "add", "main"}
    assert any("dot.1" in l for l in comps["body"])


def test_trip_count_weighting():
    comps = H._split_computations(SYNTH)
    weights, _ = H._call_weights(SYNTH, comps)
    assert weights["main"] == 1.0
    assert weights["body"] == 5.0          # constant(5) in the condition


def test_dot_flops_with_trip_counts():
    pc = H.program_costs(SYNTH)
    # dot.1: 2*8*16*16 = 4096 flops x 5 trips; dot.2: 2*8*4*16 = 1024
    assert pc.flops == 5 * 4096 + 1024
    assert pc.dot_count == 2


def test_collective_stats_and_pod_classification():
    cs = H.collective_stats(SYNTH, pod_size=256)
    # all-reduce in the loop: result 8*16*4B=512B; n=4 -> wire 2*512*3/4
    ar_once = 2 * 512 * 3 // 4
    assert cs.bytes_by_type["all-reduce"] == 5 * ar_once
    # all-gather groups of 256 devices spanning 512 => cross-pod (DCN)
    assert cs.dcn_bytes > 0
    assert cs.ici_bytes == 5 * ar_once


def test_shape_bytes():
    assert H._shape_bytes("f32[8,16]") == 512
    assert H._shape_bytes("bf16[2,3] whatever pred[7]") == 12 + 7
    assert H._shape_bytes("(f32[4], s32[2])") == 16 + 8


def test_roofline_row_terms():
    r = RooflineRow(arch="x", shape="train_4k", mesh="single", chips=256,
                    hlo_flops=197e12 * 256, hlo_bytes=819e9 * 256,
                    ici_bytes=200e9, dcn_bytes=0.0,
                    model_flops=0.75 * 197e12 * 256)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.t_collective == pytest.approx(1.0)
    assert r.useful_flops_frac == pytest.approx(0.75)
    assert r.roofline_frac == pytest.approx(0.75)
    assert r.dominant in ("compute", "memory", "collective")


def test_roofline_dominant_term():
    r = RooflineRow(arch="x", shape="s", mesh="single", chips=1,
                    hlo_flops=1e12, hlo_bytes=1e12, ici_bytes=0,
                    dcn_bytes=0, model_flops=1e12)
    # 1e12 bytes / 819e9 = 1.22 s >> 1e12/197e12 flops
    assert r.dominant == "memory"
