"""End-to-end heterogeneous training: a ~100M-parameter decoder LM.

The full production path on host devices: sharded synthetic corpus ->
capacity plan (unequal "nodes", one degrading mid-run) -> prefetching
loader -> SPMD weighted train step -> straggler replanning ->
checkpointing. This is the paper's Figure-1 pipeline in one script.

Run (full, ~100M params, a few hundred steps — takes a while on CPU):
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python examples/het_train.py --steps 300

Quick check:
  ... python examples/het_train.py --steps 20 --small
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs.base import (HetConfig, ModelConfig, OptimizerConfig,
                                ShapeConfig, TrainConfig)
from repro.core import capacity
from repro.core.straggler import StragglerMonitor
from repro.data.dataset import ShardedDataset
from repro.data.loader import PrefetchLoader
from repro.data.sampler import HetSampler
from repro.data.synthetic import build_synthetic_corpus
from repro.launch import steps as steps_mod
from repro.launch.sharding import batch_specs, named
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true",
                    help="~6M params instead of ~100M (quick check)")
    ap.add_argument("--ckpt-dir", default="/tmp/het_train_example")
    args = ap.parse_args()

    if args.small:
        cfg = ModelConfig(name="het-demo-6m", num_layers=4, d_model=256,
                          num_heads=8, num_kv_heads=4, d_ff=704,
                          vocab_size=2048, remat="none")
        seq, gbatch = 64, 8
    else:
        # ~100M params: 12L x 768 (GPT-2-small-like, SwiGLU)
        cfg = ModelConfig(name="het-demo-100m", num_layers=12,
                          d_model=768, num_heads=12, num_kv_heads=12,
                          d_ff=2048, vocab_size=32000, remat="none")
        seq, gbatch = 128, 8
    model = build_model(cfg)
    print(f"[example] {cfg.name}: {cfg.param_count():,} params")

    n_dev = len(jax.devices())
    dp = min(n_dev, 4)
    mesh = jax.make_mesh((dp, 1), ("data", "model"))
    print(f"[example] mesh: data={dp} (heterogeneous 'nodes')")

    # unequal node capacities, paper-style (fast, fast, slow, slower)
    caps = [2.0, 1.5, 1.0, 0.5][:dp]
    plan = capacity.plan_capacities(gbatch, caps, headroom=1.5)
    print(f"[example] plan: rows/rank={plan.rows_per_rank.tolist()} "
          f"buffer={plan.buffer_rows} efficiency={plan.efficiency():.2f}")

    corpus = build_synthetic_corpus("/tmp/het_train_corpus",
                                    num_seqs=max(64, 2 * gbatch),
                                    seq_len=seq + 1,
                                    vocab=cfg.vocab_size,
                                    rows_per_shard=32)
    ds = ShardedDataset(corpus)
    sampler = HetSampler(ds, plan, seed=0)
    loader = PrefetchLoader(sampler, depth=2)

    tcfg = TrainConfig(model=cfg,
                       shape=ShapeConfig("ex", seq, gbatch, "train"),
                       het=HetConfig(), optimizer=OptimizerConfig(
                           lr=1e-3, warmup_steps=20,
                           total_steps=args.steps))
    with jax.set_mesh(mesh):
        state = steps_mod.init_train_state(model, tcfg, mesh,
                                           jax.random.PRNGKey(0))
        step_fn = steps_mod.build_train_step(model, tcfg, mesh)
        bspecs = named(mesh, batch_specs(cfg, mesh, plan.padded_rows))

        mgr = CheckpointManager(args.ckpt_dir, keep=2)
        monitor = StragglerMonitor(num_ranks=dp, replan_interval=50)
        step, epoch, losses = 0, 0, []
        t0 = time.time()
        while step < args.steps:
            for raw in loader.iter_epoch(epoch):
                if step >= args.steps:
                    break
                batch = jax.device_put(
                    {"inputs": jnp.asarray(raw["inputs"][:, :seq]),
                     "labels": jnp.asarray(raw["labels"][:, :seq]),
                     "weights": jnp.asarray(raw["weights"][:, :seq])},
                    bspecs)
                ts = time.time()
                state, met = step_fn(state, batch)
                dt = time.time() - ts
                losses.append(float(met["loss"]))
                step += 1
                # simulate rank 2 degrading after step 100 (thermal
                # throttling): its reported step time doubles
                times = [dt] * dp
                if step > 100 and dp > 2:
                    times[2] = dt * 2
                monitor.observe(times)
                if monitor.should_replan():
                    plan = monitor.replan(plan)
                    sampler.set_plan(plan)
                    print(f"[example] step {step}: replanned -> "
                          f"{plan.rows_per_rank.tolist()}")
                if step % 25 == 0:
                    print(f"[example] step {step:4d} "
                          f"loss {losses[-1]:.4f} ({dt * 1e3:.0f} ms)")
                if step % 100 == 0:
                    mgr.save(step, jax.device_get(state),
                             meta={"epoch": epoch})
            epoch += 1
        mgr.save(step, jax.device_get(state), meta={"epoch": epoch},
                 block=True)
    print(f"[example] {step} steps in {time.time() - t0:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    assert losses[-1] < losses[0]
    print("[example] OK")


if __name__ == "__main__":
    main()
