"""Batched serving: prefill a prompt batch, decode with a KV cache.

Uses the production serve steps (launch/steps.py) — the same lowering
the decode_32k dry-run cell proves at 512 chips — on a small model and
host devices, and reports prefill latency + decode throughput.

Run:
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  PYTHONPATH=src python examples/serve_batch.py
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base as cfgbase
from repro.configs.base import ShapeConfig
from repro.launch import steps as steps_mod
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = cfgbase.smoke_config(args.arch)
    model = build_model(cfg)
    n_dev = len(jax.devices())
    data = 2 if n_dev >= 4 else 1
    mdl = 2 if n_dev >= 4 else 1
    mesh = jax.make_mesh((data, mdl), ("data", "model"))
    max_len = args.prompt_len + args.gen
    shape = ShapeConfig("serve", max_len, args.batch, "decode")

    params = steps_mod.init_params_sharded(model, mesh,
                                           jax.random.PRNGKey(0))
    with jax.set_mesh(mesh):
        prefill = steps_mod.build_prefill_step(model, shape, mesh)
        decode = steps_mod.build_decode_step(model, shape, mesh)
        rng = np.random.default_rng(0)
        prompts = jax.device_put(
            jnp.asarray(rng.integers(0, cfg.vocab_size,
                                     (args.batch, args.prompt_len)),
                        jnp.int32),
            NamedSharding(mesh, P(("data",), None)))

        t0 = time.time()
        logits, cache = prefill(params, prompts)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0
        print(f"[serve] prefill({args.batch}x{args.prompt_len}) "
              f"{t_prefill * 1e3:.1f} ms")

        tok_sharding = NamedSharding(mesh, P(("data",)))
        tok = jax.device_put(jnp.argmax(logits, -1).astype(jnp.int32),
                             tok_sharding)
        out_tokens = [np.asarray(tok)]
        t0 = time.time()
        for i in range(args.gen):
            logits, cache = decode(params, tok, cache,
                                   jnp.int32(args.prompt_len + i))
            tok = jax.device_put(jnp.argmax(logits, -1).astype(jnp.int32),
                                 tok_sharding)
            out_tokens.append(np.asarray(tok))
        jax.block_until_ready(logits)
        t_dec = time.time() - t0
    toks = np.stack(out_tokens, 1)
    print(f"[serve] decoded {args.gen} tokens x {args.batch} seqs in "
          f"{t_dec * 1e3:.0f} ms ({args.batch * args.gen / t_dec:.1f} "
          f"tok/s)")
    print(f"[serve] sequence 0: {toks[0][:16].tolist()}")
    print("[serve] OK")


if __name__ == "__main__":
    main()
