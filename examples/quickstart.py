"""Quickstart: the HetSeq mechanism in five minutes (single CPU device).

Demonstrates the paper's core idea end to end, no mesh required:
  1. build a small decoder LM;
  2. split one global batch across four *unequal* workers
     (capacities 3:1:1:0 — the last worker is empty, paper's edge case);
  3. aggregate weighted per-worker gradients;
  4. verify the result equals single-process training EXACTLY.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfgbase
from repro.core import capacity, dummy, weighting
from repro.models.model import build_model

# -- 1. a small model (fp32 so the equivalence check is exact) -------------
cfg = dataclasses.replace(cfgbase.smoke_config("tinyllama-1.1b"),
                          compute_dtype="float32")
model = build_model(cfg)
params = model.init_params(jax.random.PRNGKey(0))
print(f"model: {cfg.name}, "
      f"{sum(p.size for p in jax.tree.leaves(params)):,} params")

# -- 2. one global batch of 10 sequences -----------------------------------
rng = np.random.default_rng(0)
G, S = 10, 32
samples = {
    "inputs": rng.integers(0, cfg.vocab_size, (G, S)).astype(np.int32),
    "labels": rng.integers(0, cfg.vocab_size, (G, S)).astype(np.int32),
}

# -- 3. single-process reference -------------------------------------------
def objective(p, batch):
    obj_sum, w_sum, _ = model.loss_fn(p, batch)
    return obj_sum, w_sum

ref_batch = {"inputs": jnp.asarray(samples["inputs"]),
             "labels": jnp.asarray(samples["labels"]),
             "weights": jnp.ones((G, S))}
(o, w), g_ref = jax.value_and_grad(objective, has_aux=True)(params,
                                                            ref_batch)
loss_ref = float(o / w)
g_ref = weighting.scale_grads(g_ref, w)
print(f"single-process loss: {loss_ref:.6f}")

# -- 4. heterogeneous split: capacities 3:1:1:0 -----------------------------
plan = capacity.plan_capacities(G, [3.0, 1.0, 1.0, 0.0])
print(f"capacity plan: rows/rank={plan.rows_per_rank.tolist()} "
      f"buffer={plan.buffer_rows} (worker 3 is EMPTY -> all-dummy)")
packed = dummy.pack_global_batch(samples, plan)
B = plan.buffer_rows
worker_batches = [
    {k: jnp.asarray(packed[k][r * B:(r + 1) * B]) for k in packed}
    for r in range(plan.num_ranks)
]
loss_het, g_het = weighting.simulate_workers(model.loss_fn, params,
                                             worker_batches)
print(f"het-aggregated loss: {float(loss_het):.6f}")

# -- 5. the invariant --------------------------------------------------------
gerr = max(float(jnp.max(jnp.abs(a - b))) for a, b in
           zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_het)))
print(f"max |grad_single - grad_het| = {gerr:.2e}")
assert gerr < 1e-5, "HetSeq invariant violated!"
print("OK — heterogeneous DP is exactly single-process training.")
